"""CI gate for the blockwise transformer embedding backbone (tier-2).

The table2 benchmark asserts the blockwise-encoder invariants in-process;
this script re-asserts the two headline claims from the UPLOADED JSON
(``benchmarks.run --json``), so a regression that breaks the chunked ==
unchunked bit-identity, lets the per-block peak activation grow with
sequence length, or silently removes the section fails the workflow on
the artifact it publishes.

    python scripts/assert_table2_transformer.py BENCH_table2.json
"""
from __future__ import annotations

import json
import sys

MIN_BLOCK_SIZES = 3       # incl. a non-dividing block and the unchunked fwd
MIN_SEQ_LENS = 3          # the {512, 2048, 8192} sweep
MIN_UNCHUNKED_GROWTH = 100.0


def parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, _, v = part.partition("=")
            out[k] = v
    return out


def main(path: str) -> None:
    with open(path) as f:
        doc = json.load(f)
    rows = {r["name"]: parse_derived(r["derived"]) for r in doc["rows"]}
    errors = []
    name = "table2/transformer_embed"
    d = rows.get(name)
    if d is None:
        errors.append(f"missing benchmark row {name!r}")
    else:
        # (a) chunked == unchunked feature bytes across block sizes
        blocks = [b for b in d.get("blocks", "").split("+") if b]
        if len(blocks) < MIN_BLOCK_SIZES:
            errors.append(f"{name}: only {len(blocks)} block sizes swept "
                          f"(need >= {MIN_BLOCK_SIZES})")
        if d.get("bit_identical") != "True":
            errors.append(f"{name}: chunked features no longer bitwise "
                          f"identical to the unchunked forward")
        # (b) per-block peak activation flat across sequence lengths
        seq_lens = [s for s in d.get("seq_lens", "").split("+") if s]
        if len(seq_lens) < MIN_SEQ_LENS:
            errors.append(f"{name}: only {len(seq_lens)} sequence lengths "
                          f"swept (need >= {MIN_SEQ_LENS})")
        peaks = [int(p) for p in d.get("peak_act_bytes", "").split("+")
                 if p]
        if not peaks or len(set(peaks)) != 1:
            errors.append(f"{name}: peak activation not flat across "
                          f"sequence lengths: {peaks}")
        if d.get("peak_act_flat") != "True":
            errors.append(f"{name}: peak_act_flat flag dropped")
        growth = float(d.get("unchunked_growth", "0x").rstrip("x"))
        if growth < MIN_UNCHUNKED_GROWTH:
            errors.append(f"{name}: unchunked comparator grew only "
                          f"{growth:.0f}x across the sweep (need >= "
                          f"{MIN_UNCHUNKED_GROWTH:.0f}x — is the "
                          f"accounting still quadratic-aware?)")
        if peaks and peaks[0] >= int(
                d.get("unchunked_peak_bytes", "0").split("+")[0] or 0):
            errors.append(f"{name}: blockwise peak {peaks[0]} is not "
                          f"below the unchunked peak")
        # (c) is asserted in-process; its flag riding the row is a
        # cheap canary for the section being truncated
        if d.get("replicas_identical") != "True":
            errors.append(f"{name}: replicas_identical flag dropped")
    if errors:
        print("transformer-embed regression:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        sys.exit(1)
    print(f"transformer embed OK (blocks={d['blocks']} bit-identical, "
          f"peak {peaks[0]} B flat over S={{{d['seq_lens']}}}, "
          f"unchunked grows {d['unchunked_growth']})")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "BENCH_table2.json")
