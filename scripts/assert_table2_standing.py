"""CI gate for the standing-query O(delta) emit accounting (tier-2).

The table2 benchmark asserts the standing-query invariants in-process;
this script re-asserts them from the UPLOADED JSON
(``benchmarks.run --json``), so a regression that stops replay emits
from firing, drops the rows-touched ratio below 10x, breaks the
streamed == one-shot bit-identity, or silently removes the section
fails the workflow on the artifact it publishes.

    python scripts/assert_table2_standing.py BENCH_table2.json
"""
from __future__ import annotations

import json
import sys

MIN_RATIO = 10.0


def parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, _, v = part.partition("=")
            out[k] = v
    return out


def main(path: str) -> None:
    with open(path) as f:
        doc = json.load(f)
    rows = {r["name"]: parse_derived(r["derived"]) for r in doc["rows"]}
    errors = []
    name = "table2/standing_query"
    d = rows.get(name)
    if d is None:
        errors.append(f"missing benchmark row {name!r}")
    else:
        if d.get("streamed_equals_one_shot") != "True":
            errors.append(f"{name}: streamed selection no longer "
                          f"bit-identical to the one-shot query")
        replays = int(d.get("replay_emits", 0))
        if replays <= 0:
            errors.append(f"{name}: replay_emits={replays} — every emit "
                          f"fell back to a full re-selection")
        ratio = float(d.get("rows_ratio", "0x").rstrip("x"))
        if ratio < MIN_RATIO:
            errors.append(f"{name}: rows_ratio={ratio:.1f}x regressed "
                          f"below {MIN_RATIO:.0f}x (emit no longer "
                          f"O(delta))")
    if errors:
        print("standing-query regression:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        sys.exit(1)
    print(f"standing-query accounting OK (replay_emits={d['replay_emits']}"
          f", rows_ratio={d['rows_ratio']}, streamed==one-shot)")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "BENCH_table2.json")
