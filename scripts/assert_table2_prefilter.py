"""CI gate for the centroid-prefilter rows-touched accounting (tier-2).

The table2 benchmark asserts the prefilter invariants in-process; this
script re-asserts them from the UPLOADED JSON (``benchmarks.run --json``),
so a gating regression that drops the ratio below 10x, breaks selection
bit-identity, or silently removes the section fails the workflow on the
artifact it publishes rather than just slowing the lane.

    python scripts/assert_table2_prefilter.py BENCH_table2.json
"""
from __future__ import annotations

import json
import sys

MIN_RATIO = 10.0


def parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, _, v = part.partition("=")
            out[k] = v
    return out


def ratio(val: str) -> float:
    return float(val.rstrip("x"))


def main(path: str) -> None:
    with open(path) as f:
        doc = json.load(f)
    rows = {r["name"]: parse_derived(r["derived"]) for r in doc["rows"]}
    errors = []

    def check(name, field, want=None, cast=str):
        if name not in rows:
            errors.append(f"missing benchmark row {name!r}")
            return None
        if field not in rows[name]:
            errors.append(f"{name}: missing field {field!r}")
            return None
        got = cast(rows[name][field])
        if want is not None and got != want:
            errors.append(f"{name}: {field}={got!r}, expected {want!r}")
        return got

    # the gated pass must touch >=10x fewer pool rows for the asserted
    # strategies, at selections bit-identical to the full-scan oracle —
    # including when the bound is degenerate (loose slack)
    ratios = {}
    for field in ("lc_rows_ratio", "coreset_rows_ratio"):
        ratios[field] = check("table2/prefilter", field, cast=ratio)
        if ratios[field] is not None and ratios[field] < MIN_RATIO:
            errors.append(f"table2/prefilter: {field}={ratios[field]:.1f}x "
                          f"regressed below {MIN_RATIO:.0f}x")
    check("table2/prefilter", "bit_identical", want="True")
    check("table2/prefilter", "loose_slack_identical", want="True")
    # and the mmap-spill path must have actually run, bit-identically
    check("table2/shard_spill", "bit_identical", want="True")
    spills = check("table2/shard_spill", "spill_events", cast=int)
    if spills is not None and spills <= 0:
        errors.append("table2/shard_spill: spill_events=0 — the spill "
                      "path went unexercised")

    if errors:
        print("prefilter/spill regression:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        sys.exit(1)
    print("prefilter accounting OK ("
          + ", ".join(f"{k}={v:.1f}x" for k, v in ratios.items())
          + f"; shard spill_events={spills})")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "BENCH_table2.json")
