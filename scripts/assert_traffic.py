"""CI gate for the open-loop traffic harness (tier-2).

``benchmarks/traffic.py`` asserts its invariants in-process; this script
re-asserts them from the UPLOADED JSON (``--json``), so a regression that
flattens the latency curve to a single point, breaks the kill-recovery
bit-identity, stops the injected kills from exercising the recovery path,
blows the bounded-degradation envelope, loses rows during an ingest
kill, unbounds the overload drill's queue memory, starves a tenant
(Jain's index), drops the retry_after_s contract from shed ops, or
breaks the admission-on/off selection bit-identity fails the workflow on
the artifact it publishes.

    python scripts/assert_traffic.py BENCH_traffic.json
"""
from __future__ import annotations

import json
import sys

# must match benchmarks.traffic.P99_DEGRADATION_BOUND
MAX_P99_RATIO = 50.0
# must match benchmarks.traffic.JAIN_MIN
JAIN_MIN = 0.9


def parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, _, v = part.partition("=")
            out[k] = v
    return out


def main(path: str) -> None:
    with open(path) as f:
        doc = json.load(f)
    rows = {r["name"]: parse_derived(r["derived"]) for r in doc["rows"]}
    errors = []

    if doc.get("failures", 0):
        errors.append(f"harness recorded {doc['failures']} in-process "
                      f"failure(s)")

    # --- the latency curve: >= 2 offered-load levels, each with per-op
    # percentiles, plus a positive saturation throughput -------------------
    loads = {n: d for n, d in rows.items()
             if n.startswith("traffic/load_")}
    if len(loads) < 2:
        errors.append(f"only {len(loads)} offered-load row(s) — a curve "
                      f"needs >= 2 levels")
    for n, d in sorted(loads.items()):
        for k in ("offered", "achieved", "p50_query_ms", "p99_query_ms",
                  "p50_push_ms", "p99_push_ms"):
            if k not in d:
                errors.append(f"{n}: missing {k!r} in derived")

    sat = rows.get("traffic/saturation")
    if sat is None:
        errors.append("missing benchmark row 'traffic/saturation'")
    elif float(sat.get("throughput_ops_s", 0)) <= 0:
        errors.append(f"traffic/saturation: throughput_ops_s="
                      f"{sat.get('throughput_ops_s')} is not positive")

    # --- graceful degradation under injected worker death -----------------
    name = "traffic/degradation"
    d = rows.get(name)
    if d is None:
        errors.append(f"missing benchmark row {name!r}")
    else:
        if d.get("killed_equals_clean") != "True":
            errors.append(f"{name}: killed-worker selections no longer "
                          f"bit-identical to the clean run")
        if int(d.get("recoveries", 0)) < 1:
            errors.append(f"{name}: recoveries="
                          f"{d.get('recoveries')} — the injected kills "
                          f"never exercised shard recovery")
        if int(d.get("restarts", 0)) < 2:
            errors.append(f"{name}: restarts={d.get('restarts')} — "
                          f"expected the embed AND propose kills to each "
                          f"restart a lane")
        ratio = float(d.get("p99_ratio", "inf").rstrip("x"))
        if ratio > MAX_P99_RATIO:
            errors.append(f"{name}: p99_ratio={ratio:.1f}x exceeds the "
                          f"{MAX_P99_RATIO:.0f}x bounded-degradation "
                          f"envelope")

    # --- kill during ingest drain: zero lost rows -------------------------
    name = "traffic/ingest_kill"
    d = rows.get(name)
    if d is None:
        errors.append(f"missing benchmark row {name!r}")
    else:
        if int(d.get("lost_rows", -1)) != 0:
            errors.append(f"{name}: lost_rows={d.get('lost_rows')} — "
                          f"rows went missing under kill-during-ingest")
        if int(d.get("restarts", 0)) < 1:
            errors.append(f"{name}: restarts={d.get('restarts')} — the "
                          f"ingest kill never fired")
        if int(d.get("rows_hw", 1 << 60)) > int(d.get("cap_rows", 0)):
            errors.append(f"{name}: ingest rows high-water "
                          f"{d.get('rows_hw')} breached the "
                          f"{d.get('cap_rows')}-row cap under kill")

    # --- overload drill: bounded memory, fair + flat under 3x saturation --
    name = "traffic/overload"
    d = rows.get(name)
    if d is None:
        errors.append(f"missing benchmark row {name!r}")
    else:
        if int(d.get("sheds", 0)) < 1:
            errors.append(f"{name}: sheds={d.get('sheds')} — the drill "
                          f"never overloaded the server")
        if d.get("retry_after_all_positive") != "True":
            errors.append(f"{name}: a shed op was missing a positive "
                          f"retry_after_s")
        if float(d.get("jain", 0)) < JAIN_MIN:
            errors.append(f"{name}: Jain's index {d.get('jain')} < "
                          f"{JAIN_MIN} — a tenant was starved")
        p99 = float(d.get("p99_admitted_ms", "inf"))
        bound = float(d.get("p99_bound_ms", 0))
        if p99 > bound:
            errors.append(f"{name}: admitted-op p99 {p99:.0f}ms outside "
                          f"the {bound:.0f}ms envelope")
        if (int(d.get("ingest_bytes_hw", 1 << 60))
                > int(d.get("ingest_cap_bytes", 0))):
            errors.append(f"{name}: ingest queue bytes high-water "
                          f"{d.get('ingest_bytes_hw')} exceeds the cap "
                          f"{d.get('ingest_cap_bytes')} — queue memory "
                          f"is unbounded again")
        if (int(d.get("inflight_hw", 1 << 60))
                > int(d.get("max_inflight", 0))):
            errors.append(f"{name}: inflight high-water "
                          f"{d.get('inflight_hw')} breached the "
                          f"admission bound {d.get('max_inflight')}")
        if int(d.get("lost_rows", -1)) != 0:
            errors.append(f"{name}: lost_rows={d.get('lost_rows')} — "
                          f"acked rows went missing under overload")

    # --- admission on/off twin: scheduling must not change selections ----
    name = "traffic/admission_twin"
    d = rows.get(name)
    if d is None:
        errors.append(f"missing benchmark row {name!r}")
    else:
        if d.get("identical") != "True":
            errors.append(f"{name}: selections diverged with admission "
                          f"control on vs off")
        if int(d.get("sheds", 0)) < 1:
            errors.append(f"{name}: sheds={d.get('sheds')} — the "
                          f"admission-on twin never shed (vacuous "
                          f"bit-identity)")
        if int(d.get("retries", 0)) < 1:
            errors.append(f"{name}: retries={d.get('retries')} — the "
                          f"client retry layer was never exercised")

    if errors:
        print("traffic-harness regression:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        sys.exit(1)
    deg = rows["traffic/degradation"]
    ovl = rows["traffic/overload"]
    print(f"traffic harness OK ({len(loads)} load levels, saturation="
          f"{rows['traffic/saturation']['throughput_ops_s']} ops/s, "
          f"killed==clean, p99_ratio={deg['p99_ratio']}, lost_rows=0, "
          f"overload: jain={ovl['jain']} sheds={ovl['sheds']} "
          f"p99={ovl['p99_admitted_ms']}ms, admission twin identical)")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "BENCH_traffic.json")
