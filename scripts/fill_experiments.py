"""Fill EXPERIMENTS.md roofline table placeholders from runs/*.json."""
import re
import sys

sys.path.insert(0, "src")
from repro.roofline.render import render  # noqa: E402

with open("EXPERIMENTS.md") as f:
    text = f.read()

main_table = render(["runs/dryrun_single.json", "runs/dryrun_multi.json"])
tppad_table = render(["runs/dryrun_tppad.json"])

text = text.replace("<!-- ROOFLINE_TABLE -->", main_table)
text = text.replace("<!-- TPPAD_TABLE -->", tppad_table)

with open("EXPERIMENTS.md", "w") as f:
    f.write(text)
print("tables filled:",
      main_table.count("\n") - 1, "+", tppad_table.count("\n") - 1, "rows")
