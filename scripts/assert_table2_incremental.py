"""CI gate for the incremental-artifact op accounting (tier-2 lane).

The table2 benchmark already asserts its invariants in-process; this
script re-asserts the incremental-artifact counts from the UPLOADED JSON
(`benchmarks.run --json`), so an O(N)-rebuild regression — or a benchmark
edit that silently drops the section — fails the workflow on the artifact
it publishes rather than just slowing the lane.

    python scripts/assert_table2_incremental.py table2_pipeline.json
"""
from __future__ import annotations

import json
import sys


def parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, _, v = part.partition("=")
            out[k] = v
    return out


def main(path: str) -> None:
    with open(path) as f:
        doc = json.load(f)
    rows = {r["name"]: parse_derived(r["derived"]) for r in doc["rows"]}
    errors = []

    def check(name, field, want=None, cast=str):
        if name not in rows:
            errors.append(f"missing benchmark row {name!r}")
            return None
        if field not in rows[name]:
            errors.append(f"{name}: missing field {field!r}")
            return None
        got = cast(rows[name][field])
        if want is not None and got != want:
            errors.append(f"{name}: {field}={got!r}, expected {want!r}")
        return got

    # a B-row push embeds exactly B rows and rebuilds only touched shards
    push_rows = check("table2/incremental_push", "push_rows", cast=int)
    check("table2/incremental_push", "embed_rows", want=push_rows, cast=int)
    touched = check("table2/incremental_push", "touched_shards", cast=int)
    check("table2/incremental_push", "rebuilt_shards", want=touched,
          cast=int)
    if touched is not None and touched >= 4:
        errors.append(f"push touched all {touched} shards: the "
                      f"untouched-shard cache hit went unexercised")
    # retrain is a head-only prob refresh: zero re-embeds
    check("table2/incremental_retrain", "embed_rows", want=0, cast=int)
    # label invalidates nothing
    check("table2/incremental_label", "artifact_rebuilds", want=0, cast=int)
    # and none of it may change selections vs from-scratch builds
    check("table2/incremental_bit_identity", "bit_identical", want="True")

    if errors:
        print("incremental-artifact regression:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        sys.exit(1)
    print(f"incremental-artifact accounting OK "
          f"(push={push_rows} rows -> {push_rows} embeds, "
          f"{touched} shards rebuilt; retrain=0 embeds; label=0 rebuilds)")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "table2_pipeline.json")
